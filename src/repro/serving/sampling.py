"""Shared on-device sample/emit core for the serving engines.

Both the static (``engine.ServeEngine``) and continuous
(``continuous.ContinuousEngine``) decode steps need the same primitive:
draw the next token per row (greedy or temperature), append it to each
live row's output buffer, and flag EOS hits — all inside jit, with no
host traffic. Kept in one place so the two engines can't drift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_and_emit(logits, temps, key, buf, live, emitted, eos):
    """One sampling + emission step for all rows.

    logits  [B, V] f32      carried logits to sample from
    temps   scalar or [B]   per-row temperature (0 = greedy)
    buf     [B, cap] i32    output token buffer
    live    [B] bool        rows still emitting (others' writes are dropped)
    emitted [B] i32         tokens emitted so far per row
    eos     int             EOS token id (-1 = never matches)

    Returns (nxt [B] i32, buf, emitted, hit_eos [B] bool, key).

    The EOS token is a stop *signal*, not output: it is neither written to
    ``buf`` nor counted in ``emitted``, so callers never see the stop token
    and token budgets/throughput count real tokens only.
    """
    b = logits.shape[0]
    key, sk = jax.random.split(key)
    greedy = jnp.argmax(logits, axis=-1)
    t = jnp.broadcast_to(jnp.asarray(temps, jnp.float32), (b,))
    # greedy rows (t == 0) discard `sampled`; divide by 1 instead of ~0 so
    # the dead branch doesn't feed +-inf logits into categorical
    safe_t = jnp.where(t > 0, t, 1.0)
    sampled = jax.random.categorical(sk, logits / safe_t[:, None])
    nxt = jnp.where(t > 0, sampled, greedy).astype(jnp.int32)
    hit_eos = nxt == eos
    emit = live & ~hit_eos
    # non-emitting rows target index buf.shape[1]; mode="drop" discards
    idx = jnp.where(emit, emitted, buf.shape[1])
    buf = buf.at[jnp.arange(b), idx].set(nxt, mode="drop")
    emitted = emitted + emit.astype(jnp.int32)
    return nxt, buf, emitted, hit_eos, key
