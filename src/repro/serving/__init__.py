"""Public serving surface.

The supported API is the curated ``__all__`` below — build engines
through ``EngineConfig`` (the one front door for engine shape/policy),
scale them out with ``Router``, and observe them through
``ServingMetrics`` / ``SpanTracer``. Everything else in the submodules
(allocators, schedulers, samplers, fault plans) is importable for tests
and experiments but is not a stability surface.
"""

from repro.serving.block_pool import (
    BlockAllocator,
    PrefixAdmit,
    blocks_needed,
    chain_hashes,
    prefix_route_key,
)
from repro.serving.config import (
    EngineConfig,
    ObservabilityConfig,
    PagingConfig,
    ParallelConfig,
    PrefixCacheConfig,
    SpecConfig,
)
from repro.serving.continuous import ContinuousEngine, ContinuousResult
from repro.serving.engine import GenerationResult, ServeEngine
from repro.serving.export import (
    EngineLiveSource,
    MetricsServer,
    RouterLiveSource,
    SnapshotWriter,
    atomic_write_json,
    render_prometheus,
)
from repro.serving.faults import FAULT_SITES, FaultPlan, FaultSpec
from repro.serving.guard import DegradationLadder, GuardConfig
from repro.serving.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RequestTrace,
    ServingMetrics,
    WindowedHistogram,
    WindowedRate,
    merge_histogram_states,
    merge_replica_summaries,
    quantile_of_state,
)
from repro.serving.slo import SloMonitor
from repro.serving.request import (
    Request,
    RequestQueue,
    RequestState,
    synthetic_trace,
)
from repro.serving.router import Router, RouterResult
from repro.serving.scheduler import NeverAdmittable, Scheduler
from repro.serving.speculative import SpeculativeEngine
from repro.serving.tracing import (
    FlightRecorder,
    SpanTracer,
    merge_traces,
    validate_trace,
)

__all__ = [
    # the one front door: typed config + engine + data-parallel router
    "EngineConfig",
    "PagingConfig",
    "PrefixCacheConfig",
    "SpecConfig",
    "ParallelConfig",
    "GuardConfig",
    "ContinuousEngine",
    "ContinuousResult",
    "Router",
    "RouterResult",
    # requests and workloads
    "Request",
    "RequestState",
    "synthetic_trace",
    # observability
    "ObservabilityConfig",
    "ServingMetrics",
    "SloMonitor",
    "SpanTracer",
    "FlightRecorder",
    "WindowedHistogram",
    "WindowedRate",
    "MetricsServer",
    "EngineLiveSource",
    "RouterLiveSource",
    "SnapshotWriter",
    "render_prometheus",
    "atomic_write_json",
    "merge_histogram_states",
    "merge_replica_summaries",
    "quantile_of_state",
    "merge_traces",
    "validate_trace",
    # secondary (kept importable; not the recommended entry points)
    "ServeEngine",
    "GenerationResult",
    "SpeculativeEngine",
    "Scheduler",
    "NeverAdmittable",
    "BlockAllocator",
    "PrefixAdmit",
    "blocks_needed",
    "chain_hashes",
    "prefix_route_key",
    "RequestQueue",
    "RequestTrace",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "DegradationLadder",
    "FaultPlan",
    "FaultSpec",
    "FAULT_SITES",
]
