from repro.serving.engine import ServeEngine, GenerationResult
from repro.serving.block_pool import (
    BlockAllocator,
    PrefixAdmit,
    blocks_needed,
    chain_hashes,
)
from repro.serving.continuous import ContinuousEngine, ContinuousResult
from repro.serving.faults import FAULT_SITES, FaultPlan, FaultSpec
from repro.serving.guard import DegradationLadder, GuardConfig
from repro.serving.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RequestTrace,
    ServingMetrics,
)
from repro.serving.tracing import SpanTracer, validate_trace
from repro.serving.speculative import SpeculativeEngine
from repro.serving.request import (
    Request,
    RequestQueue,
    RequestState,
    synthetic_trace,
)
from repro.serving.scheduler import NeverAdmittable, Scheduler
