from repro.serving.engine import ServeEngine, GenerationResult
