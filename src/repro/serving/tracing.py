"""Span tracing for the serving engine: ring-buffered lifecycle events,
exported as Chrome trace-event JSON.

The tracer records the full request lifecycle the continuous engine
drives — queued→admitted, prefill, decode bursts, speculative rounds,
preemptions, block-table growth, finish — as retrospective *complete*
spans (the engine already timestamps both ends of every phase on its own
clock), plus *instant* events for point occurrences (preemption, cache
eviction) and *counter* events for time series (queue depth, blocks in
use). Events live in a bounded ring buffer (``collections.deque``), so a
long-running engine holds the most recent ``capacity`` events and the
tracer's memory is O(capacity) no matter how long the trace; the number
of evicted events is reported as ``dropped``.

The export is the Chrome trace-event format (the JSON array flavour,
wrapped in ``{"traceEvents": [...]}``), loadable directly in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``:

* one **pid** per engine (multi-replica serving gives each replica its
  own pid, so a fleet's traces merge into one timeline);
* one **tid** per slot (``tid = slot + 1``), plus two reserved lanes:
  ``ENGINE_TID`` (0) for engine-wide phases — host scheduling, decode
  bursts, idle waits — and ``QUEUE_TID`` for pre-admission queued spans
  (a queued request has no slot yet);
* timestamps in microseconds on the engine clock (relative to run
  start), the unit the format requires.

Cost model: a disabled tracer is ``None`` at every call site (the engine
guards each event with one ``is not None`` check), so tracing off costs
one pointer comparison per event site. Enabled, an event is one tuple
append to a deque — no string formatting, no dict building until
``export``.
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

ENGINE_TID = 0  # engine-wide lane: scheduling, bursts, idle
QUEUE_TID = 1_000_000  # pre-admission lane: queued->admitted spans


def slot_tid(slot: int) -> int:
    """Trace lane of a decode slot (0 is the engine-wide lane)."""
    return slot + 1


class SpanTracer:
    """Ring-buffered trace-event recorder for one engine (one pid)."""

    def __init__(
        self,
        capacity: int = 100_000,
        pid: int = 0,
        process_name: str = "engine",
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.pid = pid
        self.process_name = process_name
        self.n_events = 0  # total recorded (>= len(buffer) once full)
        # (ph, name, tid, ts_us, dur_us, args) — dur/args may be None
        self._buf: Deque[Tuple] = deque(maxlen=capacity)
        self._threads: Dict[int, str] = {
            ENGINE_TID: "engine",
            QUEUE_TID: "queue",
        }

    # -- recording ---------------------------------------------------------

    def complete(
        self,
        name: str,
        tid: int,
        t0: float,
        t1: float,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """A span covering ``[t0, t1]`` seconds on the engine clock."""
        self.n_events += 1
        self._buf.append(("X", name, tid, t0 * 1e6, max(t1 - t0, 0.0) * 1e6, args))

    def instant(
        self,
        name: str,
        tid: int,
        t: float,
        args: Optional[Dict[str, Any]] = None,
    ) -> None:
        """A point event at ``t`` (preemption, eviction, ...)."""
        self.n_events += 1
        self._buf.append(("i", name, tid, t * 1e6, None, args))

    def counter(self, name: str, t: float, **values: float) -> None:
        """A time-series sample (rendered as a track in Perfetto)."""
        self.n_events += 1
        self._buf.append(("C", name, ENGINE_TID, t * 1e6, None, values))

    def name_thread(self, tid: int, name: str) -> None:
        self._threads[tid] = name

    def name_slots(self, n_slots: int) -> None:
        for s in range(n_slots):
            self.name_thread(slot_tid(s), f"slot {s}")

    def __len__(self) -> int:
        return len(self._buf)

    # -- export ------------------------------------------------------------

    @property
    def dropped(self) -> int:
        """Events evicted from the ring buffer (oldest-first)."""
        return self.n_events - len(self._buf)

    def events(self) -> List[Dict[str, Any]]:
        """The buffered events as Chrome trace-event dicts, metadata
        (process/thread names) first."""
        out: List[Dict[str, Any]] = [
            {
                "ph": "M",
                "name": "process_name",
                "pid": self.pid,
                "tid": ENGINE_TID,
                "ts": 0,
                "args": {"name": self.process_name},
            }
        ]
        for tid, name in sorted(self._threads.items()):
            out.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": self.pid,
                    "tid": tid,
                    "ts": 0,
                    "args": {"name": name},
                }
            )
        for ph, name, tid, ts, dur, args in self._buf:
            ev: Dict[str, Any] = {
                "ph": ph,
                "name": name,
                "pid": self.pid,
                "tid": tid,
                "ts": ts,
            }
            if ph == "X":
                ev["dur"] = dur
            elif ph == "i":
                ev["s"] = "t"  # instant scope: thread
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {
                "recorded_events": self.n_events,
                "dropped_events": self.dropped,
            },
        }

    def export(self, path: str) -> int:
        """Write the trace as Chrome trace-event JSON; returns the number
        of events written (excluding metadata)."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
            f.write("\n")
        return len(self._buf)


class FlightRecorder:
    """Per-request lifecycle event ring for postmortem bundles.

    Where ``SpanTracer`` keeps one engine-wide ring (good for timelines,
    bad for answering "what happened to request 17?" after eviction),
    the flight recorder keeps a *per-request* bounded ring of lifecycle
    events — submit, admit, first token, preemption, growth, fault,
    degradation transitions, terminal state — so a request that dies can
    be dumped as a self-contained postmortem no matter how much traffic
    followed it. Memory stays bounded two ways: each request holds at
    most ``events_per_request`` events (oldest evicted, counted as
    dropped), and at most ``max_requests`` requests are tracked at once
    (least-recently-touched evicted first). The engine discards a
    request's ring once it finishes cleanly, so steady state tracks only
    in-flight requests.

    Recording is one deque append; nothing is formatted until
    ``bundle`` builds the postmortem dict (only on FAILED / EXPIRED /
    ABORTED terminals).
    """

    def __init__(self, events_per_request: int = 64, max_requests: int = 256):
        if events_per_request < 1:
            raise ValueError("events_per_request must be >= 1")
        if max_requests < 1:
            raise ValueError("max_requests must be >= 1")
        self.events_per_request = events_per_request
        self.max_requests = max_requests
        # rid -> deque[(t, event, detail)]; insertion order == recency
        # (moved to end on every record), so eviction pops the front
        self._rings: Dict[int, Deque[Tuple[float, str, Dict[str, Any]]]] = {}
        self._recorded: Dict[int, int] = {}  # rid -> total events recorded
        self.evicted_requests = 0  # rids dropped to honour max_requests

    def record(self, rid: int, t: float, event: str, **detail: Any) -> None:
        ring = self._rings.get(rid)
        if ring is None:
            while len(self._rings) >= self.max_requests:
                old = next(iter(self._rings))
                del self._rings[old]
                self._recorded.pop(old, None)
                self.evicted_requests += 1
            ring = deque(maxlen=self.events_per_request)
            self._rings[rid] = ring
            self._recorded[rid] = 0
        else:
            # move-to-end keeps eviction least-recently-touched-first
            self._rings[rid] = self._rings.pop(rid)
        ring.append((t, event, detail))
        self._recorded[rid] += 1

    def events(self, rid: int) -> List[Dict[str, Any]]:
        """The retained events for ``rid``, oldest first."""
        out = []
        for t, event, detail in self._rings.get(rid, ()):
            ev = {"t": round(t, 6), "event": event}
            if detail:
                ev.update(detail)
            out.append(ev)
        return out

    def dropped(self, rid: int) -> int:
        """Events evicted from ``rid``'s ring (oldest-first)."""
        return self._recorded.get(rid, 0) - len(self._rings.get(rid, ()))

    def discard(self, rid: int) -> None:
        """Forget a request (called on clean finish)."""
        self._rings.pop(rid, None)
        self._recorded.pop(rid, None)

    def tracked(self) -> int:
        return len(self._rings)

    def bundle(
        self, req: Any, context: Optional[Dict[str, Any]] = None
    ) -> Dict[str, Any]:
        """Self-contained postmortem dict for a terminal request: its
        identity and final state, the retained event timeline, and the
        engine context (degradation level, fault summary, ...) at the
        time of death."""
        rid = req.rid
        state = getattr(req, "state", None)
        return {
            "rid": rid,
            "state": getattr(state, "name", str(state)),
            "error": getattr(req, "error", None),
            "arrival": getattr(req, "arrival", None),
            "deadline": getattr(req, "deadline", None),
            "prompt_len": len(getattr(req, "prompt", ()) or ()),
            "max_new_tokens": getattr(req, "max_new_tokens", None),
            "n_preemptions": getattr(req, "n_preemptions", 0),
            "tokens_emitted": len(getattr(req, "output_tokens", ()) or ()),
            "events": self.events(rid),
            "events_recorded": self._recorded.get(rid, 0),
            "events_dropped": self.dropped(rid),
            "context": dict(context or {}),
        }


def merge_traces(tracers: Sequence["SpanTracer"]) -> Dict[str, Any]:
    """Fold several tracers' buffers into one Chrome trace dict. Each
    tracer carries its own ``pid`` (the Router gives replica ``i``
    ``pid=i``), so a fleet's lanes land side by side in one Perfetto
    timeline with no tid collisions across processes."""
    events: List[Dict[str, Any]] = []
    recorded = dropped = 0
    for t in tracers:
        events.extend(t.events())
        recorded += t.n_events
        dropped += t.dropped
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "recorded_events": recorded,
            "dropped_events": dropped,
            "n_processes": len(tracers),
        },
    }


def validate_trace(
    trace: Dict[str, Any], require: Sequence[str] = ()
) -> List[str]:
    """Schema check for an exported trace (CI gate): every event carries
    the required ``ph``/``ts``/``pid`` keys, complete events carry
    ``dur``, and the trace holds at least one span per lifecycle phase.
    ``require`` names extra events (any phase — spans or instants) that
    must appear at least once; the chaos tests use it to assert fault
    markers like ``quarantine`` or ``shed`` were actually emitted.
    Returns a list of problems (empty = valid)."""
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for i, ev in enumerate(events):
        for key in ("ph", "ts", "pid"):
            if key not in ev:
                problems.append(f"event {i} missing {key!r}: {ev}")
                break
        if ev.get("ph") == "X" and "dur" not in ev:
            problems.append(f"complete event {i} missing 'dur': {ev}")
    names = {ev.get("name") for ev in events if ev.get("ph") == "X"}
    for phase in ("queued", "prefill", "request"):
        if phase not in names:
            problems.append(f"no {phase!r} span in trace")
    if not ({"decode_burst", "speculative_burst"} & names):
        problems.append("no decode_burst/speculative_burst span in trace")
    all_names = {ev.get("name") for ev in events}
    for name in require:
        if name not in all_names:
            problems.append(f"required event {name!r} not in trace")
    return problems
