"""Deterministic fault injection for the serving engine (chaos testing).

A ``FaultPlan`` is a registry of named *fail points* — places in the
continuous engine where a real deployment can lose: the allocator coming
up short at admission or mid-decode growth, a KV pool block whose
contents were corrupted in memory, a decode or verify burst producing
NaN/Inf logits, a burst that stalls on a wedged device call, or a flood
of arrivals swamping the queue. The engine consults the plan at each
site (``should_fire``); a disabled plan is ``None`` at every call site,
so chaos off costs one ``is not None`` check per site.

Firing is **deterministic and seeded**: a spec triggers on an explicit
nth check (``site@N``), on a fixed period (``every=K``), or on a seeded
Bernoulli draw (``prob=P`` — the RNG is seeded from ``(seed, site)``, so
the same plan replays the same firing sequence run after run). Each spec
carries a firing budget (``count``, default 1) so a chaos run recovers
by construction, and a site-specific integer knob (``arg``: stall
milliseconds for ``burst_stall``, flood size for ``queue_flood``,
victim slot for the corruption sites).

The plan keeps per-site ``checks`` and ``fired`` tallies; the engine
folds ``fired`` into its metrics summary as ``fault_<site>`` keys, which
is what the chaos CI smoke asserts against.

Spec strings (the ``--chaos`` flag) are semicolon-separated clauses::

    nan_logits@3                 fire on the 3rd check of that site, once
    kv_corrupt@5:count=2         fire on checks 5 and 6
    burst_stall:every=4,arg=50   every 4th check, 50 ms stall, once
    queue_flood:prob=0.25,arg=8  seeded coin per check, flood of 8

A clause with no trigger fires on the first check (``site`` ==
``site@0``).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Dict, List, Optional, Sequence

# the engine's fail-point sites, in hook order around the serve loop
FAULT_SITES = (
    "admit_shortfall",  # admission sees an empty pool: no admits this round
    "extend_shortfall",  # on-demand growth fails: forces the preempt path
    "kv_corrupt",  # NaN payload written into a victim slot's pool block
    "nan_logits",  # a victim slot's carry logits become NaN pre-burst
    "burst_stall",  # the burst wedges for `arg` ms (watchdog territory)
    "queue_flood",  # `arg` synthetic arrivals dumped on the queue at once
)


@dataclasses.dataclass
class FaultSpec:
    """One fail-point trigger: where, when, how often, and a knob."""

    site: str
    nth: Optional[int] = None  # fire on the nth check of this site (0-based)
    every: int = 0  # fire on every `every`-th check (0 = off)
    prob: float = 0.0  # seeded Bernoulli per check (0 = off)
    count: int = 1  # firing budget (0 = unlimited)
    arg: int = 0  # site-specific knob (0 = the site's default)

    def __post_init__(self):
        if self.site not in FAULT_SITES:
            raise ValueError(
                f"unknown fault site {self.site!r} "
                f"(expected one of {', '.join(FAULT_SITES)})"
            )
        if self.nth is not None and self.nth < 0:
            raise ValueError(f"{self.site}: nth must be >= 0")
        if self.every < 0:
            raise ValueError(f"{self.site}: every must be >= 0")
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"{self.site}: prob must be in [0, 1]")
        if self.count < 0:
            raise ValueError(f"{self.site}: count must be >= 0")
        if self.nth is None and self.every == 0 and self.prob == 0.0:
            self.nth = 0  # bare clause: fire on the first check


class FaultPlan:
    """A seeded set of ``FaultSpec``s the engine consults at each site."""

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.seed = seed
        self.specs: Dict[str, List[FaultSpec]] = {}
        for spec in specs:
            self.specs.setdefault(spec.site, []).append(spec)
        self.checks: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self.fired: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self._fired_of: Dict[int, int] = {}  # id(spec) -> times fired
        # one deterministic RNG stream per site: string seeds hash stably
        # (unlike tuple seeds, which go through PYTHONHASHSEED)
        self._rng: Dict[str, random.Random] = {
            site: random.Random(f"{seed}:{site}") for site in self.specs
        }
        # fired-site hook: the engine points this at the metrics facade so
        # every firing lands as a live `fault_fired{site=...}` counter the
        # exporter can serve mid-run (the end-of-run summary() keys only
        # exist once the run returns)
        self.on_fire: Optional[Callable[[str], None]] = None

    def should_fire(self, site: str, arg_default: int = 0) -> int:
        """Check the fail point ``site``. Returns 0 when no spec fires;
        on a firing, returns the spec's ``arg`` knob (``arg_default``
        when the spec left it 0), floored at 1 so a knob-less firing is
        still truthy — call sites treat the result as both the fire/no-
        fire signal and the site parameter."""
        n = self.checks[site]
        self.checks[site] = n + 1
        for spec in self.specs.get(site, ()):
            fired = self._fired_of.get(id(spec), 0)
            if spec.count and fired >= spec.count:
                continue
            hit = (
                (spec.nth is not None and n >= spec.nth)
                or (spec.every and n > 0 and n % spec.every == 0)
                or (spec.prob and self._rng[site].random() < spec.prob)
            )
            if not hit:
                continue
            self._fired_of[id(spec)] = fired + 1
            self.fired[site] += 1
            if self.on_fire is not None:
                self.on_fire(site)
            return max(spec.arg or arg_default, 1)
        return 0

    def active_sites(self) -> List[str]:
        return sorted(self.specs)

    def summary(self) -> Dict[str, float]:
        """Per-site fired counts, keyed for the metrics summary."""
        return {f"fault_{site}": float(n) for site, n in self.fired.items()}

    # -- spec-string parsing ------------------------------------------------

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Build a plan from a ``--chaos`` spec string (see module doc)."""
        specs: List[FaultSpec] = []
        for clause in text.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            head, _, opts = clause.partition(":")
            site, _, nth = head.partition("@")
            kw: Dict[str, object] = {"site": site.strip()}
            if nth:
                kw["nth"] = int(nth)
            for opt in filter(None, (o.strip() for o in opts.split(","))):
                key, eq, val = opt.partition("=")
                if not eq:
                    raise ValueError(
                        f"chaos clause {clause!r}: option {opt!r} is not "
                        "key=value"
                    )
                key = key.strip()
                if key == "prob":
                    kw[key] = float(val)
                elif key in ("nth", "every", "count", "arg"):
                    kw[key] = int(val)
                else:
                    raise ValueError(
                        f"chaos clause {clause!r}: unknown option {key!r}"
                    )
            specs.append(FaultSpec(**kw))  # type: ignore[arg-type]
        if not specs:
            raise ValueError(f"chaos spec {text!r} names no fault sites")
        return cls(specs, seed=seed)
