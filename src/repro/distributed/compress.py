"""Gradient compression with error feedback (EF-SGD / QSGD family).

Two layers:

* ``quantize_int8`` / ``dequantize_int8`` — per-tensor symmetric int8 with a
  carried residual (error feedback): ``q = Q(g + residual)``,
  ``residual' = (g + residual) - Q^{-1}(q)``. EF keeps SGD convergence under
  biased-ish rounding (Karimireddy et al. 2019).

* ``ef_allreduce_int8`` — a wire-efficient mean over a named mesh axis built
  from all_to_all + local fp32 reduction + all_gather of re-quantized
  partials: every hop moves **int8**, a ~4x traffic cut vs fp32 ring
  all-reduce (2 quantization events total, both fed back through the
  residual). Designed for the pure-DP ``pod`` axis of the production mesh,
  where gradient bytes dominate ICI (DCN) traffic; use under ``shard_map``.

Training integration: ``ef_compress_grads`` compresses the gradient pytree
before the optimizer (residual tree lives in ``OptState.residual``); the
dryrun's ``--grad-compression`` flag wires it into the train step so the
collective bytes show up in the §Roofline accounting.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def _scale_for(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    s = _scale_for(x.astype(jnp.float32))
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_int8(q: jnp.ndarray, s: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * s


def _is_float(g) -> bool:
    return (
        g is not None
        and hasattr(g, "dtype")
        and jnp.issubdtype(g.dtype, jnp.floating)
        and g.dtype != jax.dtypes.float0
        and g.size > 0
    )


def ef_compress_grads(
    grads: Pytree, residual: Optional[Pytree]
) -> Tuple[Pytree, Pytree]:
    """Quantize->dequantize each gradient leaf with error feedback.

    Returns (compressed-then-decompressed grads, new residual tree). The
    round-trip models exactly what the int8 wire format delivers; the
    residual carries the rounding error into the next step.
    """
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros_like(g, jnp.float32) if _is_float(g) else None,
            grads,
            is_leaf=lambda x: x is None,
        )

    def comp(g, r):
        if not _is_float(g):
            return g
        acc = g.astype(jnp.float32) + r
        q, s = quantize_int8(acc)
        return dequantize_int8(q, s)

    def resid(g, r):
        if not _is_float(g):
            return r
        acc = g.astype(jnp.float32) + r
        q, s = quantize_int8(acc)
        return acc - dequantize_int8(q, s)

    new_g = jax.tree.map(comp, grads, residual, is_leaf=lambda x: x is None)
    new_r = jax.tree.map(resid, grads, residual, is_leaf=lambda x: x is None)
    return new_g, new_r


def ef_allreduce_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean of ``x`` over ``axis_name`` with int8 on every wire hop.

    Must run inside shard_map/pmap over `axis_name`. x: any shape; padded to
    a multiple of the axis size on the leading (flattened) dim.
    """
    n = jax.lax.psum(1, axis_name)  # portable axis-size idiom (all jax versions)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    q, s = quantize_int8(chunks)
    # reduce-scatter phase: everyone receives its chunk from all peers (int8)
    q_t = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0)
    s_all = jax.lax.all_gather(s, axis_name)  # tiny scalar vector
    partial = jnp.sum(
        q_t.reshape(n, -1).astype(jnp.float32) * s_all[:, None], axis=0
    ) / n
    # all-gather phase: redistribute re-quantized partial sums (int8)
    pq, ps = quantize_int8(partial)
    gq = jax.lax.all_gather(pq, axis_name)  # [n, chunk] int8
    gs = jax.lax.all_gather(ps, axis_name)
    out = (gq.astype(jnp.float32) * gs[:, None]).reshape(-1)
    out = out[: x.size]
    return out.reshape(x.shape)
