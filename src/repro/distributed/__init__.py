import jax as _jax

from repro.distributed.compress import (
    quantize_int8,
    dequantize_int8,
    ef_compress_grads,
    ef_allreduce_int8,
)
from repro.distributed.accum import microbatch_grads
from repro.distributed.elastic import choose_mesh_shape, elastic_mesh
from repro.distributed.straggler import StepMonitor

# shard_map moved from jax.experimental to the jax namespace (~0.6); resolve
# once here so callers of the distributed collectives don't fork on version.
shard_map = getattr(_jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F401
