from repro.distributed.compress import (
    quantize_int8,
    dequantize_int8,
    ef_compress_grads,
    ef_allreduce_int8,
)
from repro.distributed.accum import microbatch_grads
from repro.distributed.elastic import choose_mesh_shape, elastic_mesh
from repro.distributed.straggler import StepMonitor
