"""Straggler / hang mitigation for the training launcher.

SPMD steps are synchronous: one slow host stretches everyone. Inside the XLA
program there is nothing to schedule around, so mitigation lives at the
launcher plane:

  * ``StepMonitor`` — EWMA of step wall-time with a z-score alarm; flags
    stragglers (persistent slowdowns -> operator signal to cordon the host)
    and hard-hangs (watchdog deadline -> raise, triggering checkpoint-resume,
    possibly on fewer nodes via the elastic mesh).
  * data-skip on resume — the deterministic data pipeline is addressed by
    step, so a restarted job does not need to replay the stream.

At 1000+ nodes the same monitor feeds the cluster scheduler: .flag_file is
touched with the offending step so an external supervisor can reschedule.
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class StepMonitor:
    def __init__(
        self,
        slow_factor: float = 2.0,
        hang_timeout_s: float = 600.0,
        ewma: float = 0.9,
        flag_file: Optional[str] = None,
    ):
        self.slow_factor = slow_factor
        self.hang_timeout_s = hang_timeout_s
        self.ewma = ewma
        self.flag_file = flag_file
        self.mean_dt: Optional[float] = None
        self.slow_steps = 0
        self.total_steps = 0
        self._deadline: Optional[float] = None
        self._watchdog: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._hang = threading.Event()

    # -- hang watchdog -----------------------------------------------------
    def _watch(self):
        while not self._stop.wait(1.0):
            d = self._deadline
            if d is not None and time.monotonic() > d:
                self._hang.set()
                self._flag("hang")
                return

    def start(self):
        self._watchdog = threading.Thread(target=self._watch, daemon=True)
        self._watchdog.start()
        return self

    def stop(self):
        self._stop.set()

    def check_hang(self):
        if self._hang.is_set():
            raise TimeoutError(
                f"step exceeded hang timeout {self.hang_timeout_s}s — "
                "checkpoint-resume (possibly elastic) required"
            )

    # -- per-step accounting -------------------------------------------------
    def step_begin(self):
        self._deadline = time.monotonic() + self.hang_timeout_s

    def step_end(self) -> bool:
        """Returns True if this step was a straggler."""
        now = time.monotonic()
        dt = now - (self._deadline - self.hang_timeout_s)
        self._deadline = None
        self.total_steps += 1
        slow = False
        if self.mean_dt is not None and dt > self.slow_factor * self.mean_dt:
            self.slow_steps += 1
            slow = True
            self._flag(f"slow step {self.total_steps}: {dt:.2f}s vs {self.mean_dt:.2f}s")
        self.mean_dt = (
            dt
            if self.mean_dt is None
            else self.ewma * self.mean_dt + (1 - self.ewma) * dt
        )
        return slow

    def _flag(self, msg: str):
        if self.flag_file:
            try:
                with open(self.flag_file, "a") as f:
                    f.write(f"{time.time():.0f} {msg}\n")
            except OSError:
                pass
