"""Microbatched gradient accumulation.

Splits the per-step batch into ``n_micro`` sequential microbatches inside a
``lax.scan``: activation memory drops by ``n_micro`` (the binding constraint
for the 100B train configs — see EXPERIMENTS §Perf), gradients are averaged
in fp32, and the data-parallel all-reduce happens **once** per step (XLA
hoists it out of the scan because the psum consumes the final accumulator),
which also batches the collective.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def microbatch_grads(
    loss_fn: Callable[[Pytree, Pytree], jnp.ndarray],
    params: Pytree,
    batch: Pytree,
    n_micro: int,
) -> Tuple[jnp.ndarray, Pytree]:
    """Returns (mean loss, mean grads). Splits batch dim 0 into n_micro."""
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(params, batch)
        return loss, grads

    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % n_micro {n_micro}"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    grad_fn = jax.value_and_grad(loss_fn, allow_int=True)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = grad_fn(params, mb)
        g_acc = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32) if a is not None else None,
            g_acc,
            g,
            is_leaf=lambda x: x is None,
        )
        return (loss_acc + loss, g_acc), None

    def zero_like(g):
        if g is None or not hasattr(g, "dtype"):
            return None
        if g.dtype == jax.dtypes.float0 or not jnp.issubdtype(g.dtype, jnp.floating):
            return None
        return jnp.zeros(g.shape, jnp.float32)

    g0 = jax.tree.map(zero_like, jax.eval_shape(lambda p: grad_fn(p, jax.tree.map(lambda x: x[0], micro))[1], params))
    (loss_sum, g_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), g0), micro
    )
    inv = 1.0 / n_micro
    grads = jax.tree.map(
        lambda g: g * inv if g is not None else None,
        g_sum,
        is_leaf=lambda x: x is None,
    )
    return loss_sum * inv, grads
