"""Elastic mesh management.

On (re)start the launcher calls ``elastic_mesh`` with whatever devices are
alive; it factorizes the count into the closest-to-requested (data, model)
shape (model parallelism capped by attention-head divisibility), and the
checkpoint layer's reshard-on-load places the saved full arrays onto the new
mesh — so losing a host mid-run costs one restart, not a re-run.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def _divisors(n: int):
    return sorted(d for d in range(1, n + 1) if n % d == 0)


def choose_mesh_shape(
    n_devices: int,
    preferred_model: int = 16,
    model_divides: Optional[int] = None,
) -> Tuple[int, int]:
    """Pick (data, model) for `n_devices`.

    model axis: largest divisor of n_devices that is <= preferred_model and
    (if given) divides `model_divides` (e.g. head count or d_ff granularity).
    """
    best = 1
    for d in _divisors(n_devices):
        if d > preferred_model:
            break
        if model_divides is not None and model_divides % d != 0:
            continue
        best = d
    return n_devices // best, best


def elastic_mesh(
    preferred_model: int = 16,
    model_divides: Optional[int] = None,
    multi_pod: bool = False,
    devices: Optional[Sequence] = None,
):
    """Build a mesh from the devices that are actually alive."""
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs)
    if multi_pod and n % 2 == 0 and n >= 4:
        data, model = choose_mesh_shape(n // 2, preferred_model, model_divides)
        return jax.make_mesh((2, data, model), ("pod", "data", "model"), devices=devs)
    data, model = choose_mesh_shape(n, preferred_model, model_divides)
    return jax.make_mesh((data, model), ("data", "model"), devices=devs)
