"""Pure-jnp oracles for the SLiM Pallas kernels.

Each oracle consumes exactly the HBM layout the kernel consumes and defines
the semantics the kernel must reproduce (tests assert allclose across
shape/dtype sweeps). They reuse ``repro.core.packing`` so the oracle and the
model's XLA execution path (core.compressed) are the same math.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import unpack_dense_24, unpack_int4


def _dequant(codes: jnp.ndarray, scale, bits: int) -> jnp.ndarray:
    half = 2 ** (bits - 1)
    return codes.astype(jnp.float32) * (scale / half)


def int4_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    w_packed: jnp.ndarray,  # uint8 [K/2, N]
    scale,  # () f32 per-tensor or [K/g, 1, N] group
    bits: int = 4,
    group_size: int = 0,
) -> jnp.ndarray:
    codes = unpack_int4(w_packed)  # [K, N]
    if group_size:
        k, n = codes.shape
        w = _dequant(codes.reshape(k // group_size, group_size, n), scale, bits)
        w = w.reshape(k, n)
    else:
        w = _dequant(codes, scale, bits)
    return jnp.dot(x.astype(jnp.float32), w)


def sparse24_matmul_ref(
    x: jnp.ndarray,  # [M, K]
    packed_vals: jnp.ndarray,  # uint8 [K/4, N]
    packed_idx: jnp.ndarray,  # uint8 [K/8, N]
    scale,  # () f32
    bits: int = 4,
) -> jnp.ndarray:
    k = x.shape[1]
    codes = unpack_dense_24(packed_vals, packed_idx, k)  # [K, N]
    w = _dequant(codes, scale, bits)
    return jnp.dot(x.astype(jnp.float32), w)


def slim_linear_ref(
    x: jnp.ndarray,  # [M, K]
    packed_vals: jnp.ndarray,  # uint8 [K/4, N]
    packed_idx: jnp.ndarray,  # uint8 [K/8, N]
    scale,  # () f32
    lora_l: jnp.ndarray,  # [K, R]
    lora_r: jnp.ndarray,  # [R, N]
    inv_act_scale: Optional[jnp.ndarray] = None,  # [K]
    bits: int = 4,
) -> jnp.ndarray:
    """The full deployed SLiM layer: y = (x*s) @ W_deq + (x @ L) @ R."""
    k = x.shape[1]
    codes = unpack_dense_24(packed_vals, packed_idx, k)
    w = _dequant(codes, scale, bits)
    xs = x.astype(jnp.float32)
    xb = xs if inv_act_scale is None else xs * inv_act_scale[None, :]
    y = jnp.dot(xb, w)
    y = y + jnp.dot(jnp.dot(xs, lora_l.astype(jnp.float32)), lora_r.astype(jnp.float32))
    return y


def group_quantize_ref(x: jnp.ndarray, g: int = 128, bits: int = 4):
    """Group-absmax quantize oracle -> (codes uint8 [K/2,N], scales [K/g,1,N])."""
    from repro.core.packing import pack_int4

    k, n = x.shape
    half = 2 ** (bits - 1)
    qmax = half - 1
    xg = x.astype(jnp.float32).reshape(k // g, g, n)
    s = jnp.max(jnp.abs(xg), axis=1, keepdims=True)
    s = jnp.where(s <= 0, 1.0, s)
    codes = jnp.clip(jnp.round(xg / s * half), -qmax, qmax).reshape(k, n)
    return pack_int4(codes.astype(jnp.int8)), s.astype(jnp.float32)


def group_dequantize_ref(codes, scales, g: int = 128, bits: int = 4):
    k = codes.shape[0] * 2
    n = codes.shape[1]
    half = 2 ** (bits - 1)
    dense = unpack_int4(codes).astype(jnp.float32)
    return (dense.reshape(k // g, g, n) * (scales / half)).reshape(k, n)


def flash_decode_ref(q, k, v, kv_len):
    """Single-token attention oracle. q [B,H,dh]; k/v [B,S,H,dh]; kv_len [B]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = k.shape[1]
    pos = jnp.arange(s)[None, None, :]
    scores = jnp.where(pos < kv_len[:, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
