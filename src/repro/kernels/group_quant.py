"""Pallas TPU kernels: group-absmax quantize / dequantize.

The paper's PEFT phase (§3.4) runs the straight-through estimator with
custom Triton (de)quantization kernels; these are the TPU Pallas analogues
(DESIGN.md §4). Each grid step owns a ``(bg*g, bn)`` block: the quantizer
reduces |max| per (group, column), emits int4 codes packed 2-per-byte plus
f32 scales; the dequantizer inverts it. Both are elementwise+reduction VPU
work with 128-lane-aligned layouts; fused into the adapter matmul producers
on TPU, they keep the STE round-trip out of HBM.

Layout (matches core.packing / core.compressed):
    x      f32/bf16 [K, N], groups of ``g`` along K
    codes  uint8 [K/2, N]   (int4 nibbles, packed along K)
    scales f32 [K/g, 1, N]
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import pick_block, resolve_interpret, unpack_int4_block


def _quant_kernel(x_ref, codes_ref, scale_ref, *, g: int, bits: int):
    x = x_ref[...].astype(jnp.float32)  # [bg*g, bn]
    rows, bn = x.shape
    xg = x.reshape(rows // g, g, bn)
    half = float(2 ** (bits - 1))
    qmax = half - 1
    s = jnp.max(jnp.abs(xg), axis=1, keepdims=True)  # [bg, 1, bn]
    s = jnp.where(s <= 0, 1.0, s)
    codes = jnp.clip(jnp.round(xg / s * half), -qmax, qmax).astype(jnp.int32)
    codes = codes.reshape(rows, bn)
    lo = codes[0::2, :] & 0xF
    hi = codes[1::2, :] & 0xF
    codes_ref[...] = (lo | (hi << 4)).astype(jnp.uint8)
    scale_ref[...] = s.astype(jnp.float32)


def _dequant_kernel(codes_ref, scale_ref, o_ref, *, g: int, bits: int):
    codes = unpack_int4_block(codes_ref[...])  # [bg*g, bn] int32
    rows, bn = codes.shape
    half = float(2 ** (bits - 1))
    xg = codes.reshape(rows // g, g, bn).astype(jnp.float32)
    o_ref[...] = (xg * (scale_ref[...] / half)).reshape(rows, bn).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("g", "bits", "bk", "bn", "interpret"))
def group_quantize(
    x: jnp.ndarray,  # [K, N]
    g: int = 128,
    bits: int = 4,
    bk: int = 512,
    bn: int = 128,
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (codes uint8 [K/2, N], scales f32 [K/g, 1, N])."""
    k, n = x.shape
    assert k % g == 0 and g % 2 == 0
    bk = max(g, pick_block(k, bk))
    assert bk % g == 0
    bn = pick_block(n, bn)
    grid = (k // bk, n // bn)
    interpret = resolve_interpret(interpret)
    codes, scales = pl.pallas_call(
        functools.partial(_quant_kernel, g=g, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bk // 2, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // g, 1, bn), lambda i, j: (i, 0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k // 2, n), jnp.uint8),
            jax.ShapeDtypeStruct((k // g, 1, n), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return codes, scales


@functools.partial(
    jax.jit, static_argnames=("g", "bits", "bk", "bn", "out_dtype", "interpret")
)
def group_dequantize(
    codes: jnp.ndarray,  # uint8 [K/2, N]
    scales: jnp.ndarray,  # f32 [K/g, 1, N]
    g: int = 128,
    bits: int = 4,
    bk: int = 512,
    bn: int = 128,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> jnp.ndarray:
    k = codes.shape[0] * 2
    n = codes.shape[1]
    bk = max(g, pick_block(k, bk))
    bn = pick_block(n, bn)
    grid = (k // bk, n // bn)
    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, g=g, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk // 2, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // g, 1, bn), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), out_dtype),
        interpret=interpret,
    )(codes, scales)
