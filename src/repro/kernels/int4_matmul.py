"""Pallas TPU kernel: fused int4-dequant matmul (W4A16).

    y[M, N] = x[M, K] @ dequant(w_packed[K/2, N])

The packed int4 weights stream HBM->VMEM in ``(bk/2, bn)`` blocks (half the
bytes of an int8 weight, a quarter of bf16); the VPU unpacks + dequantizes
(SLiM-Quant per-tensor scale, or per-128-group scales) and the MXU consumes
dense fp32 ``(bm, bk) x (bk, bn)`` dots with fp32 accumulation carried in the
output block across the k-grid.

Grid: ``(M/bm, N/bn, K/bk)`` row-major — k innermost so the out block stays
resident; Pallas double-buffers the block DMAs (the TPU analogue of Marlin's
global->shared pipelining; DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import dequant_dense_int4, pick_block, resolve_interpret


def _kernel_pertensor(x_ref, w_ref, scale_ref, o_ref, *, bits: int, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = dequant_dense_int4(w_ref[...], scale_ref[0, 0], bits)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )


def _kernel_group(x_ref, w_ref, scale_ref, o_ref, *, bits: int, nk: int, group_size: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    from repro.kernels.common import unpack_int4_block

    codes = unpack_int4_block(w_ref[...])  # [bk, bn]
    bk, bn = codes.shape
    half = float(2 ** (bits - 1))
    scales = scale_ref[...]  # [bk/g, 1, bn]
    w = (
        codes.reshape(bk // group_size, group_size, bn).astype(jnp.float32)
        * (scales / half)
    ).reshape(bk, bn)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit,
    static_argnames=("bits", "group_size", "bm", "bn", "bk", "interpret"),
)
def int4_matmul(
    x: jnp.ndarray,  # [M, K]
    w_packed: jnp.ndarray,  # uint8 [K/2, N]
    scale: jnp.ndarray,  # () or [K/g, 1, N]
    bits: int = 4,
    group_size: int = 0,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> jnp.ndarray:
    m, k = x.shape
    n = w_packed.shape[-1]
    assert w_packed.shape[-2] * 2 == k
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    if group_size:
        # a k-block must cover whole groups
        assert bk % group_size == 0 or group_size % bk == 0
        bk = max(bk, group_size)
    nk = k // bk
    grid = (m // bm, n // bn, nk)

    if group_size == 0:
        scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)
        kern = functools.partial(_kernel_pertensor, bits=bits, nk=nk)
        scale_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    else:
        scale_arr = jnp.asarray(scale, jnp.float32)  # [K/g, 1, N]
        kern = functools.partial(
            _kernel_group, bits=bits, nk=nk, group_size=group_size
        )
        scale_spec = pl.BlockSpec(
            (bk // group_size, 1, bn), lambda i, j, kk: (kk, 0, j)
        )

    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w_packed, scale_arr)
