"""Pallas TPU kernel: paged flash-decoding single-token attention.

Same math as ``flash_decode`` — online-softmax attention of one query per
batch row against that row's KV history — but K/V live in a *shared block
pool* (``[n_blocks, bs, H, dh]``) instead of per-row contiguous lanes, and
each row's history is the sequence of pool blocks named by its block table
(``[B, max_blocks]`` int32). Like ``flash_decode`` it is the TPU form of
the serving hot path, validated standalone against the XLA oracle: the
engine's paged decode (``layers.attention_layer``) reaches the same math
by materializing ``cache[table]`` gathers, which is exact everywhere but
bandwidth-wasteful; this kernel is the swap-in that avoids it on TPU.

The block table rides in as a scalar-prefetch operand
(``PrefetchScalarGridSpec``): block index maps read ``tbl[i, j]`` to DMA
the j-th logical block of row i straight from its physical pool slot — the
gather happens in the DMA engine; nothing of size ``max_blocks * bs`` is
materialized. Grid ``(B, max_blocks)``, sequence innermost; the running
(max, denom, numerator) triple persists in VMEM scratch across one row's
blocks exactly as in ``flash_decode`` (the online-softmax core is shared).

Unallocated table entries point at physical block 0 (the engine's null
block); they sit beyond ``kv_len`` and are masked the same way ragged fill
levels already are. A row with ``kv_len == 0`` emits zeros.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import resolve_interpret
from repro.kernels.flash_decode import online_softmax_finish, online_softmax_update


def _kernel(
    tbl_ref,  # scalar-prefetch [B, M] int32 block table
    q_ref,  # [1, H, dh]
    k_ref,  # [1, bs, H, dh]  physical block tbl[i, j]
    v_ref,  # [1, bs, H, dh]
    len_ref,  # [1, 1] int32: valid kv length for this batch row
    o_ref,  # [1, H, dh]
    m_ref,  # scratch [H, 1] running max
    l_ref,  # scratch [H, 1] running denom
    acc_ref,  # scratch [H, dh] running numerator
    *,
    bs: int,
    nm: int,
    scale: float,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [H, dh]
    k = k_ref[0].astype(jnp.float32)  # [bs, H, dh]
    v = v_ref[0].astype(jnp.float32)
    scores = jnp.einsum("hd,shd->hs", q, k) * scale  # [H, bs]

    # logical position of each entry in this block = j*bs + offset; the
    # paged layout keeps each row's logical positions dense in [0, kv_len)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[0, 0]  # [1, bs]
    online_softmax_update(scores, v, valid, m_ref, l_ref, acc_ref)

    @pl.when(j == nm - 1)
    def _finish():
        online_softmax_finish(o_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode(
    q: jnp.ndarray,  # [B, H, dh]
    k_pool: jnp.ndarray,  # [n_blocks, bs, H, dh]  (KV heads pre-expanded to H)
    v_pool: jnp.ndarray,  # [n_blocks, bs, H, dh]
    block_tables: jnp.ndarray,  # [B, max_blocks] int32 physical block ids
    kv_len: jnp.ndarray,  # [B] int32 valid lengths
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> jnp.ndarray:
    b, h, dh = q.shape
    bs = k_pool.shape[1]
    nm = block_tables.shape[1]
    scale = 1.0 / (dh ** 0.5)
    lens = kv_len.reshape(b, 1).astype(jnp.int32)
    tbl = block_tables.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nm),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i, j, tbl: (i, 0, 0)),
            pl.BlockSpec((1, bs, h, dh), lambda i, j, tbl: (tbl[i, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, h, dh), lambda i, j, tbl: (tbl[i, j], 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, tbl: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j, tbl: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
    )
    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, nm=nm, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(tbl, q, k_pool, v_pool, lens)
