"""Pallas TPU kernel: the full deployed SLiM layer, fused.

    y = (x * inv_act_scale) @ decompress24(dequant(vals, idx)) + (x @ L) @ R

One pallas_call reads ``x`` once per (m, k) block and produces both the
compressed-base contribution and the low-rank correction:

  * grid ``(M/bm, N/bn, K/bk)``, k innermost, n middle, m outer;
  * the LoRA intermediate ``t = x @ L`` ([bm, R], fp32) is accumulated in a
    VMEM scratch during the ``n == 0`` k-sweep and **reused** for every other
    n block of the same m row (scratch persists across sequential grid steps
    on a TPU core) — LoRA left-matmul FLOPs are paid once per m row, not per
    (m, n) tile;
  * at the last k step the kernel adds ``t @ R[:, n-block]`` into the output.

The rank R stays resident in VMEM (r = 0.1 d -> [bk, R] and [bm, R] blocks
are ~1-3 MB at d=12288, within the ~16 MB VMEM budget).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import dequant_sparse24, pick_block, resolve_interpret


def _kernel(
    x_ref,  # [bm, bk]
    vals_ref,  # [bk/4, bn]
    idx_ref,  # [bk/8, bn]
    scale_ref,  # [1, 1]
    ias_ref,  # [1, bk] inv act scale
    l_ref,  # [bk, R]
    r_ref,  # [R, bn]
    o_ref,  # [bm, bn]
    t_ref,  # scratch [bm, R] f32
    *,
    bits: int,
    nk: int,
):
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)

    # LoRA left factor: accumulate t = x @ L once per m row (n == 0 sweep)
    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _tinit():
        t_ref[...] = jnp.zeros_like(t_ref)

    @pl.when(j == 0)
    def _taccum():
        t_ref[...] += jnp.dot(
            x, l_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
        )

    # compressed base: scale activations, decompress+dequant weights, MXU dot
    xb = x * ias_ref[0, :][None, :]
    w = dequant_sparse24(vals_ref[...], idx_ref[...], scale_ref[0, 0], bits)
    o_ref[...] += jnp.dot(xb, w, preferred_element_type=jnp.float32)

    # final k step: add the low-rank correction for this n block
    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] += jnp.dot(
            t_ref[...], r_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )


@functools.partial(
    jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret")
)
def slim_linear(
    x: jnp.ndarray,  # [M, K]
    packed_vals: jnp.ndarray,  # uint8 [K/4, N]
    packed_idx: jnp.ndarray,  # uint8 [K/8, N]
    scale: jnp.ndarray,  # ()
    lora_l: jnp.ndarray,  # [K, R]
    lora_r: jnp.ndarray,  # [R, N]
    inv_act_scale: Optional[jnp.ndarray] = None,  # [K]
    bits: int = 4,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> jnp.ndarray:
    m, k = x.shape
    n = packed_vals.shape[-1]
    r = lora_l.shape[-1]
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = max(8, pick_block(k, bk))
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    ias = (
        jnp.ones((1, k), jnp.float32)
        if inv_act_scale is None
        else jnp.asarray(inv_act_scale, jnp.float32).reshape(1, k)
    )

    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, r), jnp.float32)],
        interpret=interpret,
    )(x, packed_vals, packed_idx, scale_arr, ias, lora_l, lora_r)
