"""Pallas TPU kernel: flash-decoding single-token attention.

    o[B, H, dh] = softmax(q[B, H, dh] . K[B, S, H, dh]^T / sqrt(dh)) @ V

The serving hot path next to the SLiM matmul: decode attention over a long
KV cache is pure HBM streaming. The kernel splits the KV sequence across
the grid (FlashDecoding-style split-K) and maintains the online-softmax
running (max, sum, weighted-value) triple in VMEM scratch, so each K/V
block is read exactly once and nothing of size S is materialized.

Grid: ``(B, S/bs)`` — sequence split innermost; the running stats persist
in scratch across the s-steps of one batch row; the final step normalizes
into the output block. Positions beyond ``kv_len`` (per batch row) are
masked, supporting ragged cache fill levels across the batch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import pick_block, resolve_interpret

_NEG = -1e30


def online_softmax_update(scores, v, valid, m_ref, l_ref, acc_ref):
    """One FlashDecoding online-softmax accumulation step (runs inside a
    kernel body; shared by ``flash_decode`` and ``paged_decode``).

    scores [H, bs] f32   raw (scaled) q.k scores for this K/V block
    v      [bs, H, dh]   value block
    valid  [1, bs] bool  positions that exist for this batch row
    m/l/acc              VMEM scratch: running max, denom, numerator

    Masked positions contribute exactly zero: ``p`` is zeroed under
    ``valid`` directly, so a fully-masked block (or row — kv_len == 0)
    leaves (m, l, acc) untouched instead of averaging uninitialized V
    through ``exp(_NEG - _NEG) == 1``.
    """
    scores = jnp.where(valid, scores, _NEG)
    m_prev = m_ref[...]  # [H, 1]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)  # rescale of old stats
    p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)  # [H, bs]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("hs,shd->hd", p, v)
    m_ref[...] = m_new


def online_softmax_finish(o_ref, m_ref, l_ref, acc_ref):
    """Normalize the running numerator into the output block; rows that
    never saw a valid position (l == 0) emit zeros, not garbage."""
    l = l_ref[...]
    o_ref[0] = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-20), 0.0).astype(
        o_ref.dtype
    )


def _kernel(
    q_ref,  # [1, H, dh]
    k_ref,  # [1, bs, H, dh]
    v_ref,  # [1, bs, H, dh]
    len_ref,  # [1, 1] int32: valid kv length for this batch row
    o_ref,  # [1, H, dh]
    m_ref,  # scratch [H, 1] running max
    l_ref,  # scratch [H, 1] running denom
    acc_ref,  # scratch [H, dh] running numerator
    *,
    bs: int,
    ns: int,
    scale: float,
):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [H, dh]
    k = k_ref[0].astype(jnp.float32)  # [bs, H, dh]
    v = v_ref[0].astype(jnp.float32)
    scores = jnp.einsum("hd,shd->hs", q, k) * scale  # [H, bs]

    pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[0, 0]  # [1, bs]
    online_softmax_update(scores, v, valid, m_ref, l_ref, acc_ref)

    @pl.when(s_idx == ns - 1)
    def _finish():
        online_softmax_finish(o_ref, m_ref, l_ref, acc_ref)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def flash_decode(
    q: jnp.ndarray,  # [B, H, dh]
    k: jnp.ndarray,  # [B, S, H, dh]  (KV heads pre-expanded to H)
    v: jnp.ndarray,  # [B, S, H, dh]
    kv_len: jnp.ndarray,  # [B] int32 valid lengths
    bs: int = 512,
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> jnp.ndarray:
    b, h, dh = q.shape
    s = k.shape[1]
    bs = pick_block(s, bs)
    ns = s // bs
    scale = 1.0 / (dh ** 0.5)
    lens = kv_len.reshape(b, 1).astype(jnp.int32)

    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, ns=ns, scale=scale),
        grid=(b, ns),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, bs, h, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, h, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lens)
