"""Jit'd public wrappers around the SLiM Pallas kernels.

``slim_linear_op`` consumes a ``repro.core.compressed.SlimLinear`` directly,
so model code can swap the XLA path (``slim_linear_apply``) for the kernel
path with one flag. The default ``interpret=None`` resolves per backend
(``common.default_interpret``): compiled on TPU, interpret mode (bit-exact
semantics, Python-speed) on CPU hosts — no caller has to thread the flag.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.compressed import SlimLinear
from repro.kernels.flash_decode import flash_decode
from repro.kernels.group_quant import group_dequantize, group_quantize
from repro.kernels.paged_decode import paged_decode
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.sparse24_matmul import sparse24_matmul
from repro.kernels.slim_linear import slim_linear


def slim_linear_op(
    p: SlimLinear, x: jnp.ndarray, interpret: Optional[bool] = None,
    skip_lora: bool = False,
) -> jnp.ndarray:
    """Kernel-path equivalent of ``core.compressed.slim_linear_apply``.

    ``skip_lora=True`` is the backbone-only fast path (the self-speculative
    draft model): it routes straight to the no-LoRA kernels —
    ``sparse24_matmul`` / ``int4_matmul`` — so the draft forward never pays
    the fused kernel's LoRA scratch accumulation, adapter dequantization,
    or either low-rank matmul."""
    assert p.packed_vals.ndim == 2, "kernel path takes unstacked layers"
    if p.fmt == "sparse24":
        if p.lora_l is not None and not skip_lora:
            return slim_linear(
                x,
                p.packed_vals,
                p.packed_idx,
                p.scale,
                p.lora_l,
                p.lora_r,
                inv_act_scale=p.inv_act_scale,
                bits=p.bits,
                interpret=interpret,
            )
        xs = x if p.inv_act_scale is None else x * p.inv_act_scale
        return sparse24_matmul(
            xs, p.packed_vals, p.packed_idx, p.scale, bits=p.bits, interpret=interpret
        )
    # dense int4 path
    xs = x if p.inv_act_scale is None else x * p.inv_act_scale
    y = int4_matmul(
        xs,
        p.packed_vals,
        p.scale,
        bits=p.bits,
        group_size=p.group_size,
        interpret=interpret,
    )
    if p.lora_l is not None and not skip_lora:
        y = y + jnp.dot(jnp.dot(x, p.lora_l), p.lora_r)
    return y


__all__ = [
    "int4_matmul",
    "sparse24_matmul",
    "slim_linear",
    "slim_linear_op",
    "group_quantize",
    "group_dequantize",
    "flash_decode",
    "paged_decode",
]
