"""Pallas TPU kernel: fused 2:4-decompress + int4-dequant matmul.

    y[M, N] = x[M, K] @ decompress24(dequant(vals, idx))

HBM traffic per weight block is 3 bits/position (int4 survivors + 2-bit
metadata) vs 16 for bf16 — a 5.3x weight-bandwidth cut, which is the binding
resource for decode shapes. Decompression is a select-by-iota expansion in
VMEM (no scatter; TPU has no 2:4 sparse MXU so compute stays dense — the
documented semantic change from the paper's Sparse Marlin, DESIGN.md §4).

Grid: ``(M/bm, N/bn, K/bk)``, fp32 accumulation in the resident out block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import dequant_sparse24, pick_block, resolve_interpret


def _kernel(x_ref, vals_ref, idx_ref, scale_ref, o_ref, *, bits: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = dequant_sparse24(vals_ref[...], idx_ref[...], scale_ref[0, 0], bits)
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32), w, preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret")
)
def sparse24_matmul(
    x: jnp.ndarray,  # [M, K]
    packed_vals: jnp.ndarray,  # uint8 [K/4, N]
    packed_idx: jnp.ndarray,  # uint8 [K/8, N]
    scale: jnp.ndarray,  # ()
    bits: int = 4,
    bm: int = 128,
    bn: int = 128,
    bk: int = 256,
    interpret: Optional[bool] = None,  # None = compile on TPU, else interpret
) -> jnp.ndarray:
    m, k = x.shape
    n = packed_vals.shape[-1]
    assert packed_vals.shape[-2] * 4 == k
    assert packed_idx.shape[-2] * 8 == k
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = max(8, pick_block(k, bk))
    assert bk % 8 == 0, f"bk={bk} must cover whole packed-idx bytes"
    grid = (m // bm, n // bn, k // bk)
    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    interpret = resolve_interpret(interpret)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, packed_vals, packed_idx, scale_arr)
