"""Shared in-kernel helpers for the SLiM Pallas TPU kernels.

These run *inside* ``pl.pallas_call`` kernel bodies: pure jnp on VMEM-resident
blocks. The unpack routines mirror ``repro.core.packing`` bit-for-bit — the
packing module writes HBM layouts, these read them back on the VPU.

TPU adaptation notes (DESIGN.md §4): nibble/2-bit unpacking is elementwise
integer VPU work on (8,128)-lane registers; the 2:4 decompression is a
select-by-iota (no scatter), which vectorizes cleanly. The MXU consumes the
resulting dense fp32 block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_int4_block(packed: jnp.ndarray) -> jnp.ndarray:
    """uint8 [k, n] -> int8-as-int32 [2k, n], sign-extended nibbles."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    lo = (lo ^ 8) - 8
    hi = (hi ^ 8) - 8
    k, n = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k, n)


def unpack_idx2_block(packed: jnp.ndarray) -> jnp.ndarray:
    """uint8 [k, n] -> uint8-as-int32 [4k, n] of 2-bit fields."""
    parts = [((packed >> (2 * s)) & 0x3).astype(jnp.int32) for s in range(4)]
    k, n = packed.shape
    return jnp.stack(parts, axis=1).reshape(4 * k, n)


def decompress_24_block(vals: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """vals int32 [k/2, n] (slot-major), idx int32 [k/2, n] in {0..3}
    -> dense int32 [k, n] with zeros at pruned positions (select-by-iota)."""
    khalf, n = vals.shape
    g = khalf // 2
    v = vals.reshape(g, 2, n)
    i = idx.reshape(g, 2, n)
    pos = jax.lax.broadcasted_iota(jnp.int32, (g, 4, 2, n), 1)
    hit = (i[:, None, :, :] == pos).astype(jnp.int32)
    dense = jnp.sum(hit * v[:, None, :, :], axis=2)  # [g, 4, n]
    return dense.reshape(4 * g, n)


def dequant_dense_int4(packed: jnp.ndarray, scale, bits: int = 4) -> jnp.ndarray:
    """packed uint8 [bk/2, bn] + scale -> f32 [bk, bn]."""
    codes = unpack_int4_block(packed)
    half = float(2 ** (bits - 1))
    return codes.astype(jnp.float32) * (scale / half)


def dequant_sparse24(
    packed_vals: jnp.ndarray, packed_idx: jnp.ndarray, scale, bits: int = 4
) -> jnp.ndarray:
    """packed_vals uint8 [bk/4, bn], packed_idx uint8 [bk/8, bn] + scale
    -> dense f32 [bk, bn]."""
    vals = unpack_int4_block(packed_vals)  # [bk/2, bn]
    idx = unpack_idx2_block(packed_idx)  # [bk/2, bn]
    dense = decompress_24_block(vals, idx)  # [bk, bn]
    half = float(2 ** (bits - 1))
    return dense.astype(jnp.float32) * (scale / half)


def default_interpret() -> bool:
    """Default ``interpret`` for every Pallas entry point: compile on TPU,
    interpret (bit-exact, Python-speed) everywhere else. Callers can still
    force either mode explicitly; passing ``None`` selects this default, so
    TPU hosts get compiled kernels without threading the flag by hand."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


def pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two block <= preferred that divides dim (>=8)."""
    b = min(preferred, dim)
    while dim % b != 0 and b > 1:
        b //= 2
    return max(b, 1)
