"""Deterministic synthetic LM data pipeline.

Two generators:
  * zipf — i.i.d. Zipf-distributed tokens (matches LLM vocab frequency
    statistics; good for shape/throughput work, nothing to learn).
  * markov — an order-1 Markov chain with a low-entropy, banded transition
    matrix. A model trained on it reaches materially-below-chance loss in a
    few hundred steps, which is what the accuracy-proxy benchmarks need to
    *discriminate* compression methods (random-init models show ~no signal).

Sharding: each host draws only its slice of the global batch
(`host_id`/`host_count`), derived from a per-step fold of the base seed —
identical global stream regardless of topology, no cross-host I/O. This is
the standard deterministic-data recipe for 1000-node runs (no data server).
Calibration batches reuse the same stream at a reserved step offset.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    kind: str = "markov"  # markov | zipf
    seed: int = 0
    zipf_a: float = 1.2
    markov_band: int = 8  # plausible next-token fan-out
    d_model: int = 0  # for embeddings-input archs (frame/patch stubs)
    vision_tokens: int = 0
    input_mode: str = "tokens"


_CALIB_STEP_OFFSET = 1_000_000_007


def make_markov_sampler(vocab: int, band: int, seed: int):
    """Returns sample(rng, shape) drawing from a banded Markov chain."""
    rng = np.random.default_rng(seed)
    # each token t transitions to one of `band` successors with decaying probs
    successors = (np.arange(vocab)[:, None] * 31 + rng.integers(0, vocab, (vocab, band))) % vocab
    probs = np.exp(-0.7 * np.arange(band))
    probs = probs / probs.sum()
    successors_j = jnp.asarray(successors)
    probs_j = jnp.asarray(probs, jnp.float32)

    def sample(key, batch: int, seq: int) -> jnp.ndarray:
        k0, k1 = jax.random.split(key)
        tok0 = jax.random.randint(k0, (batch,), 0, vocab)

        def step(tok, k):
            choice = jax.random.choice(k, band, (batch,), p=probs_j)
            nxt = successors_j[tok, choice]
            return nxt, nxt

        keys = jax.random.split(k1, seq - 1)
        _, rest = jax.lax.scan(step, tok0, keys)
        return jnp.concatenate([tok0[None], rest], 0).T  # [batch, seq]

    return sample


def _zipf_sample(key, cfg: SyntheticLMConfig, batch: int) -> jnp.ndarray:
    # inverse-CDF zipf over a finite vocab
    ranks = jnp.arange(1, cfg.vocab_size + 1, dtype=jnp.float32)
    w = ranks ** (-cfg.zipf_a)
    p = w / jnp.sum(w)
    return jax.random.choice(
        key, cfg.vocab_size, (batch, cfg.seq_len), p=p
    ).astype(jnp.int32)


def _batch_for_step(
    cfg: SyntheticLMConfig, step: int, host_id: int, host_count: int, sampler=None
) -> Dict[str, jnp.ndarray]:
    assert cfg.global_batch % host_count == 0
    local = cfg.global_batch // host_count
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), host_id
    )
    if cfg.kind == "markov":
        toks = sampler(key, local, cfg.seq_len + 1)
    else:
        toks = _zipf_sample(key, dataclasses.replace(cfg, seq_len=cfg.seq_len + 1), local)
    batch = {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "labels": toks[:, 1:].astype(jnp.int32),
    }
    if cfg.input_mode == "embeddings":
        ek = jax.random.fold_in(key, 7)
        batch["embeds"] = (
            jax.random.normal(ek, (local, cfg.seq_len, cfg.d_model), jnp.float32)
            * 0.02
            + jax.nn.one_hot(batch["tokens"] % cfg.d_model, cfg.d_model) * 0.5
        )
    if cfg.vision_tokens:
        vk = jax.random.fold_in(key, 11)
        batch["vision_embeds"] = jax.random.normal(
            vk, (local, cfg.vision_tokens, cfg.d_model), jnp.float32
        ) * 0.02
    return batch


def synthetic_batches(
    cfg: SyntheticLMConfig,
    host_id: int = 0,
    host_count: int = 1,
    start_step: int = 0,
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite deterministic batch stream, resumable at any step."""
    sampler = (
        make_markov_sampler(cfg.vocab_size, cfg.markov_band, cfg.seed)
        if cfg.kind == "markov"
        else None
    )
    step = start_step
    while True:
        yield _batch_for_step(cfg, step, host_id, host_count, sampler)
        step += 1


def calibration_batch(
    cfg: SyntheticLMConfig, n_samples: int = 16, host_id: int = 0, host_count: int = 1
) -> Dict[str, jnp.ndarray]:
    """Held-out calibration data (reserved step range; paper uses 128 C4 seqs)."""
    ccfg = dataclasses.replace(cfg, global_batch=n_samples * host_count)
    sampler = (
        make_markov_sampler(cfg.vocab_size, cfg.markov_band, cfg.seed)
        if cfg.kind == "markov"
        else None
    )
    return _batch_for_step(ccfg, _CALIB_STEP_OFFSET, host_id, host_count, sampler)
