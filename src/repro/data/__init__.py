from repro.data.synthetic import (
    SyntheticLMConfig,
    synthetic_batches,
    calibration_batch,
    make_markov_sampler,
)
