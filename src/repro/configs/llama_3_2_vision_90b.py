"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision;
unverified].

Period of 5: four self-attention decoder layers + one gated cross-attention
layer over the (stub) vision embeddings — 20 cross-attn layers in 100,
matching the interleave ratio. Vision frontend is a STUB per spec:
input_specs() provides projected patch embeddings [B, 1024, d_model]."""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = (
    LayerSpec("attn"), LayerSpec("attn"), LayerSpec("attn"), LayerSpec("attn"),
    LayerSpec("cross_attn"),
)

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256, rope_theta=5e5,
    vision_tokens=1024,
    period=_PERIOD,
)

REDUCED = ModelConfig(
    name="llama-3.2-vision-reduced",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=512, vision_tokens=16,
    dtype="float32", q_chunk=64, vocab_chunk=64,
    period=(LayerSpec("attn"), LayerSpec("cross_attn")),
)
