"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=16384, vocab_size=32768, sliding_window=4096, rope_theta=1e6,
    n_experts=8, top_k=2,
    period=(LayerSpec("attn", moe=True),),
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=512, sliding_window=32, n_experts=4, top_k=2,
    dtype="float32", q_chunk=64, vocab_chunk=64, moe_group=64,
    period=(LayerSpec("attn", moe=True),),
)
