"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32 => MHA) d_ff=6912
vocab=50304. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=6912, vocab_size=50304,
    period=(LayerSpec("attn"),),
)

REDUCED = ModelConfig(
    name="stablelm-3b-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, d_head=16,
    d_ff=256, vocab_size=512, dtype="float32", q_chunk=64, vocab_chunk=64,
    period=(LayerSpec("attn"),),
)
