"""Paper-scale configs for the SLiM reproduction itself.

slim-tiny  (~10M): the accuracy-proxy grid (benchmarks/bench_accuracy.py) —
small enough to train to signal on CPU in minutes, OPT-125M-shaped.
slim-100m (~100M): the end-to-end example (examples/finetune_e2e.py), the
"train ~100M model for a few hundred steps" deliverable."""
from repro.models.config import LayerSpec, ModelConfig

TINY = ModelConfig(
    name="slim-tiny",
    n_layers=4, d_model=256, n_heads=8, n_kv_heads=8, d_head=32,
    d_ff=768, vocab_size=2048, dtype="float32",
    q_chunk=128, vocab_chunk=128,
    period=(LayerSpec("attn"),),
)

SMALL_100M = ModelConfig(
    name="slim-100m",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=2304, vocab_size=8192, dtype="float32",
    q_chunk=256, vocab_chunk=256,
    period=(LayerSpec("attn"),),
)

CONFIG = TINY
REDUCED = TINY
