"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202048, rope_theta=5e5,
    n_experts=16, top_k=1,
    period=(LayerSpec("attn", moe=True),),
)

REDUCED = ModelConfig(
    name="llama4-scout-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=512, n_experts=4, top_k=1,
    dtype="float32", q_chunk=64, vocab_chunk=64, moe_group=64,
    period=(LayerSpec("attn", moe=True),),
)
