"""mamba2-1.3b [ssm] — 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].
expand=2 -> d_inner=4096, head_dim=64 -> 64 SSD heads, 1 B/C group."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
    ssm_conv=4, ssm_chunk=256,
    period=(LayerSpec("ssm"),),
)

REDUCED = ModelConfig(
    name="mamba2-1.3b-reduced",
    n_layers=2, d_model=128, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_groups=1,
    ssm_chunk=16, dtype="float32", q_chunk=64, vocab_chunk=64,
    period=(LayerSpec("ssm"),),
)
