"""Architecture registry: --arch <id> resolution for launch/ and tests."""
from importlib import import_module
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES = {
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "yi-34b": "repro.configs.yi_34b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "musicgen-large": "repro.configs.musicgen_large",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "slim-tiny": "repro.configs.slim_paper",
    "slim-100m": "repro.configs.slim_paper",
}

ASSIGNED: List[str] = [
    "mistral-large-123b",
    "yi-34b",
    "qwen3-0.6b",
    "stablelm-3b",
    "mixtral-8x22b",
    "llama4-scout-17b-a16e",
    "mamba2-1.3b",
    "musicgen-large",
    "jamba-v0.1-52b",
    "llama-3.2-vision-90b",
]


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    mod = import_module(_MODULES[name])
    if name == "slim-100m":
        return mod.SMALL_100M
    if name == "slim-tiny":
        return mod.TINY
    return mod.REDUCED if reduced else mod.CONFIG


def list_configs() -> List[str]:
    return list(_MODULES)
