"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_head=64,
    d_ff=3072, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    period=(LayerSpec("attn"),),
)

REDUCED = ModelConfig(
    name="qwen3-0.6b-reduced",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab_size=512, qk_norm=True, dtype="float32",
    q_chunk=64, vocab_chunk=64, period=(LayerSpec("attn"),),
)
