"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
llama-arch GQA [arXiv:2403.04652; hf]"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab_size=64000, rope_theta=5e6,
    period=(LayerSpec("attn"),),
)

REDUCED = ModelConfig(
    name="yi-34b-reduced",
    n_layers=2, d_model=112, n_heads=7, n_kv_heads=1, d_head=16,
    d_ff=256, vocab_size=500, dtype="float32", q_chunk=64, vocab_chunk=64,
    period=(LayerSpec("attn"),),
)
