"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba+attn 1:7 interleave, MoE every 2nd
layer [arXiv:2403.19887; hf].

Period of 8 layers: attention at index 3, SSM elsewhere; MoE on odd indices
(1,3,5,7). SSM blocks use our SSD (Mamba2) formulation — state 128,
head_dim 64, 8 B/C groups (Jamba ships Mamba-1; the SSD variant is the
TPU-native matmul-rich equivalent, noted in DESIGN.md §4)."""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec("attn" if i == 3 else "ssm", moe=(i % 2 == 1)) for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_groups=8,
    ssm_conv=4, ssm_chunk=256,
    period=_PERIOD,
)

_REDUCED_PERIOD = tuple(
    LayerSpec("attn" if i == 1 else "ssm", moe=(i % 2 == 1)) for i in range(4)
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=512, n_experts=4, top_k=2,
    ssm_state=16, ssm_head_dim=16, ssm_groups=2, ssm_chunk=16,
    dtype="float32", q_chunk=64, vocab_chunk=64, moe_group=64,
    period=_REDUCED_PERIOD,
)
