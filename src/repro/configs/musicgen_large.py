"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32 MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
The EnCodec frontend is a STUB per spec: input_specs() provides precomputed
frame embeddings [B, S, d_model]; the backbone predicts codebook tokens."""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=2048, input_mode="embeddings",
    period=(LayerSpec("attn"),),
)

REDUCED = ModelConfig(
    name="musicgen-large-reduced",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=8, d_head=16,
    d_ff=256, vocab_size=256, input_mode="embeddings",
    dtype="float32", q_chunk=64, vocab_chunk=64,
    period=(LayerSpec("attn"),),
)
