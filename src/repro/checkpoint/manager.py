"""Fault-tolerant checkpointing (no orbax dependency).

Layout per step::

    <dir>/step_00000042.tmp-<nonce>/   # written first
        shard_<proc>.npz               # this process's addressable leaf data
        manifest.json                  # structure + shapes + dtypes + meta
    <dir>/step_00000042/               # atomic rename on completion

Guarantees / features:
  * **atomicity** — a checkpoint directory only appears under its final name
    after every array and the manifest are fully written + fsync'd; crashes
    mid-write leave only ``.tmp-*`` litter that restore ignores and the next
    save garbage-collects.
  * **resume-latest-valid** — ``latest_step`` scans for the newest directory
    whose manifest round-trips; partial/corrupt steps are skipped.
  * **elastic restore** — arrays are saved unsharded (gathered from
    addressable shards); on restore they are ``device_put`` against whatever
    sharding the *new* mesh prescribes, so a job restarted on a different
    device count resumes transparently (reshard-on-load).
  * **async** — ``CheckpointManager.save(..., blocking=False)`` hands the
    (host-copied) tree to a writer thread; training overlaps the I/O.
  * **retention** — keeps the newest ``keep`` checkpoints.

Pytree encoding: leaves are flattened with ``jax.tree_util.tree_flatten``;
the manifest stores the serialized treedef string for a structural check and
restore happens against a caller-provided ``like`` tree (structure master),
which keeps custom nodes (SlimLinear, OptState) intact including their
static aux data.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import uuid
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def _to_host(tree: Pytree) -> list:
    leaves = jax.tree.leaves(tree)
    return [np.asarray(x) for x in leaves]


def save_pytree(base: str, step: int, tree: Pytree, meta: Optional[Dict] = None,
                process_index: int = 0) -> str:
    """Write one checkpoint atomically. Returns the final directory."""
    os.makedirs(base, exist_ok=True)
    # GC stale tmp dirs from crashed writers
    for d in os.listdir(base):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(base, d), ignore_errors=True)

    final = _step_dir(base, step)
    tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)

    leaves = _to_host(tree)
    treedef = jax.tree.structure(tree)
    shard_path = os.path.join(tmp, f"shard_{process_index}.npz")
    np.savez(shard_path, **{f"leaf_{i}": a for i, a in enumerate(leaves)})
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(a.shape) for a in leaves],
        "dtypes": [str(a.dtype) for a in leaves],
        "meta": meta or {},
        "time": time.time(),
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _valid(base: str, step: int) -> bool:
    d = _step_dir(base, step)
    mpath = os.path.join(d, "manifest.json")
    try:
        with open(mpath) as f:
            m = json.load(f)
        return m.get("step") == step and os.path.exists(
            os.path.join(d, "shard_0.npz")
        )
    except (OSError, json.JSONDecodeError):
        return False


def latest_step(base: str) -> Optional[int]:
    if not os.path.isdir(base):
        return None
    steps = []
    for d in os.listdir(base):
        m = _STEP_RE.match(d)
        if m:
            steps.append(int(m.group(1)))
    for s in sorted(steps, reverse=True):
        if _valid(base, s):
            return s
    return None


def restore_pytree(
    base: str,
    step: int,
    like: Pytree,
    shardings: Optional[Pytree] = None,
) -> Pytree:
    """Restore against a structure-master ``like`` tree.

    ``shardings``: optional tree (same structure) of jax.sharding.Sharding —
    arrays are placed directly onto the (possibly different-size) new mesh.
    """
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    like_leaves, treedef = jax.tree.flatten(like)
    if len(like_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target tree has {len(like_leaves)}"
        )
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda x: x is None)
        if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for a, proto, sh in zip(leaves, like_leaves, shard_leaves, strict=True):
        arr = a.astype(proto.dtype) if hasattr(proto, "dtype") else a
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


class CheckpointManager:
    """Retention + async writes + auto-resume."""

    def __init__(self, base: str, keep: int = 3, process_index: int = 0):
        self.base = base
        self.keep = keep
        self.process_index = process_index
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.base)
            if (m := _STEP_RE.match(d))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(_step_dir(self.base, s), ignore_errors=True)

    def save(self, step: int, tree: Pytree, meta: Optional[Dict] = None,
             blocking: bool = True):
        self.wait()  # one in-flight write at a time
        host_leaves = _to_host(tree)  # copy out BEFORE training mutates buffers
        treedef = jax.tree.structure(tree)
        host_tree = jax.tree.unflatten(treedef, host_leaves)

        def _write():
            try:
                save_pytree(self.base, step, host_tree, meta, self.process_index)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            if self._error is not None:
                err, self._error = self._error, None
                raise err
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def restore_latest(
        self, like: Pytree, shardings: Optional[Pytree] = None
    ) -> Optional[tuple]:
        s = latest_step(self.base)
        if s is None:
            return None
        return s, restore_pytree(self.base, s, like, shardings)
